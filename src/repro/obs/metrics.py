"""A tiny dependency-free metrics logger: counters / gauges / timers → JSONL.

One :class:`MetricsLogger` per run.  Events are appended to a JSONL file
as they happen (``path=None`` keeps the logger in-memory only — every
call still works, nothing is written), human-readable lines go through
:meth:`info` (stdout by default), and the accumulated counters/gauges are
flushed as one ``summary`` record on :meth:`close`.  Stdlib only — the
runtime loops and benchmarks must not grow a telemetry dependency.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from contextlib import contextmanager
from typing import Dict, Iterator, List, Optional, TextIO, Union


def _jsonable(v):
    """Coerce numpy / jax scalars (anything float()-able) for json."""
    if isinstance(v, (str, int, bool)) or v is None:
        return v
    try:
        return float(v)
    except (TypeError, ValueError):
        return str(v)


_GIT_SHA_CACHE: Dict[str, str] = {}


def git_sha(repo_dir: Optional[str] = None, short: int = 12) -> str:
    """The current commit's SHA, for stamping artifacts with provenance.

    Reads ``.git/HEAD`` directly (fast, no subprocess) and falls back to
    ``git rev-parse`` for packed refs / worktrees; ``"unknown"`` outside a
    repository.  Cached per directory."""
    root = os.path.abspath(repo_dir or os.getcwd())
    if root in _GIT_SHA_CACHE:
        return _GIT_SHA_CACHE[root]
    sha = "unknown"
    d = root
    while True:
        head = os.path.join(d, ".git", "HEAD")
        if os.path.exists(head):
            try:
                with open(head) as f:
                    ref = f.read().strip()
                if ref.startswith("ref:"):
                    ref_path = os.path.join(d, ".git", ref[4:].strip())
                    if os.path.exists(ref_path):
                        with open(ref_path) as f:
                            sha = f.read().strip()
                else:
                    sha = ref
            except OSError:
                pass
            break
        parent = os.path.dirname(d)
        if parent == d:
            break
        d = parent
    if sha == "unknown":
        try:
            sha = subprocess.run(
                ["git", "rev-parse", "HEAD"], cwd=root, text=True,
                capture_output=True, timeout=10).stdout.strip() or "unknown"
        except (OSError, subprocess.SubprocessError):
            sha = "unknown"
    sha = sha[:short] if sha != "unknown" else sha
    _GIT_SHA_CACHE[root] = sha
    return sha


class MetricsLogger:
    """Counters, gauges, timers and structured events, JSONL on disk.

    ``path`` is the JSONL sink (parent directories are created; None =
    in-memory only).  ``echo`` is where :meth:`info` renders
    human-readable lines: ``True`` (default) = ``sys.stdout``, ``False``
    = silent (the structured record is still kept), or any text stream.
    ``run`` / extra ``meta`` are stamped on every record so concatenated
    logs stay attributable.
    """

    def __init__(self, path: Optional[str] = None,
                 echo: Union[bool, TextIO] = True,
                 run: Optional[str] = None, **meta):
        self.path = path
        # True is kept symbolic: sys.stdout resolves at info() time, so
        # stream redirection (pytest capture) after construction works
        self.echo: Union[bool, TextIO] = False if echo is False else echo
        self.counters: Dict[str, float] = {}
        self.gauges: Dict[str, float] = {}
        self.records: List[dict] = []  # in-memory mirror (tests, describe)
        self._meta = dict(meta)
        if run is not None:
            self._meta["run"] = run
        self._fh: Optional[TextIO] = None
        self._t0 = time.monotonic()
        if path:
            parent = os.path.dirname(os.path.abspath(path))
            os.makedirs(parent, exist_ok=True)
            self._fh = open(path, "a", buffering=1)

    # ---- structured events -------------------------------------------------
    def log(self, event: str, **fields) -> dict:
        rec = {"t": round(time.monotonic() - self._t0, 9), "event": event}
        rec.update(self._meta)
        rec.update({k: _jsonable(v) for k, v in fields.items()})
        self.records.append(rec)
        if self._fh is not None:
            self._fh.write(json.dumps(rec) + "\n")
        return rec

    def info(self, msg: str, **fields) -> None:
        """A human-readable line: rendered to ``echo`` verbatim AND kept
        as a structured ``info`` record."""
        if self.echo is not False:
            print(msg, file=sys.stdout if self.echo is True else self.echo)
        self.log("info", msg=msg, **fields)

    # ---- counters / gauges / timers ---------------------------------------
    def inc(self, name: str, value: float = 1.0) -> float:
        self.counters[name] = self.counters.get(name, 0.0) + float(value)
        return self.counters[name]

    def gauge(self, name: str, value: float) -> None:
        self.gauges[name] = float(value)

    @contextmanager
    def timer(self, name: str, **fields) -> Iterator[None]:
        """Times the with-block: accumulates ``<name>_s`` as a counter and
        logs one ``timer`` record."""
        t0 = time.perf_counter()
        try:
            yield
        finally:
            dt = time.perf_counter() - t0
            self.inc(f"{name}_s", dt)
            self.inc(f"{name}_n", 1.0)
            self.log("timer", name=name, seconds=dt, **fields)

    # ---- lifecycle ---------------------------------------------------------
    def summary(self) -> dict:
        return {"counters": dict(self.counters), "gauges": dict(self.gauges)}

    def close(self) -> None:
        if self.counters or self.gauges:
            self.log("summary", **{f"c:{k}": v
                                   for k, v in self.counters.items()},
                     **{f"g:{k}": v for k, v in self.gauges.items()})
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def __enter__(self) -> "MetricsLogger":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
