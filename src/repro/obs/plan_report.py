"""The planner's candidate sweep as a serializable audit artifact.

``Planner(keep_report=True)`` records EVERY candidate each search prices
— depth × chunks × codec × staging × path split (``_search_section``) and
chunks × path split × staging (``plan_all_to_all``) — with its priced
total and a rejection reason, into a :class:`PlanReport` that serializes
next to ``SyncPlan.to_json``.  The report answers "why this plan":
which shapes were searched, what each candidate cost, and by how much
the winner won (ties resolve to the earlier candidate — the planner's
documented tie-break order).
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field
from typing import List, Optional, Sequence, Tuple


@dataclass(frozen=True)
class Candidate:
    """One priced candidate of a section search.  ``rejected`` is None
    for the winner, else the reason it lost."""

    total_s: float
    strategy: str
    scatter_depth: int
    chunks: int
    codec: Optional[str] = None
    mid_codec: Optional[str] = None
    staging: Optional[str] = None
    path_split: Optional[Tuple[Tuple[str, float], ...]] = None
    pipelined: bool = False
    describe: str = ""
    rejected: Optional[str] = None


@dataclass(frozen=True)
class SectionReport:
    """One search: every candidate priced for one section (or one
    all-to-all exchange), the winner's index, and the winner's schedule
    as searched (``CommSchedule.to_dict()`` — before any bucket chunk
    adjustment or lane-offset stagger the caller applies afterwards)."""

    name: str
    kind: str  # "section" | "all_to_all"
    shape: Tuple[int, ...]
    candidates: Tuple[Candidate, ...]
    winner: int
    winner_schedule: Optional[dict] = None


@dataclass
class PlanReport:
    sections: List[SectionReport] = field(default_factory=list)

    @staticmethod
    def build_section(name: str, kind: str, shape: Sequence[int],
                      priced: Sequence[Tuple[float, dict, object]]
                      ) -> SectionReport:
        """Assemble one :class:`SectionReport` from the search's priced
        list ``[(total_s, knob dict, schedule)]`` (list order = the
        planner's tie-break order).  The winner is the FIRST candidate
        at the minimum — exactly ``min(...)``'s choice — and every
        other candidate gets its rejection reason."""
        totals = [t for t, _, _ in priced]
        best = min(totals)
        win = totals.index(best)
        cands: List[Candidate] = []
        for i, (total, knobs, sched) in enumerate(priced):
            if i == win:
                reason = None
            elif total > best:
                reason = f"slower: +{(total - best) / max(best, 1e-30):.2%}"
            else:
                reason = "tie: earlier candidate wins"
            cands.append(Candidate(
                total_s=total,
                describe=sched.describe() if sched is not None else "",
                rejected=reason, **knobs))
        winner_sched = priced[win][2]
        return SectionReport(
            name=name, kind=kind, shape=tuple(int(s) for s in shape),
            candidates=tuple(cands), winner=win,
            winner_schedule=(winner_sched.to_dict()
                             if winner_sched is not None else None))

    # ---- serialization -----------------------------------------------------
    def to_json(self) -> str:
        return json.dumps([asdict(s) for s in self.sections], indent=2)

    @classmethod
    def from_json(cls, text: str) -> "PlanReport":
        sections = []
        for s in json.loads(text):
            cands = tuple(Candidate(
                **{**c, "path_split": (tuple((p, f) for p, f
                                             in c["path_split"])
                                       if c.get("path_split") else None)})
                for c in s["candidates"])
            sections.append(SectionReport(
                name=s["name"], kind=s["kind"], shape=tuple(s["shape"]),
                candidates=cands, winner=s["winner"],
                winner_schedule=s.get("winner_schedule")))
        return cls(sections)

    def describe(self) -> str:
        lines = [f"PlanReport: {len(self.sections)} searches"]
        for s in self.sections:
            w = s.candidates[s.winner]
            lines.append(
                f"  {s.name} [{s.kind}] shape={s.shape}: "
                f"{len(s.candidates)} candidates, winner "
                f"#{s.winner} {w.strategy} depth={w.scatter_depth} "
                f"chunks={w.chunks} staging={w.staging} "
                f"split={w.path_split} -> {w.total_s * 1e6:.2f} us")
            for i, c in enumerate(s.candidates):
                if i == s.winner:
                    continue
                lines.append(f"    #{i} {c.strategy} d={c.scatter_depth} "
                             f"c={c.chunks} stg={c.staging} "
                             f"split={c.path_split}: {c.rejected}")
        return "\n".join(lines)


# ---- plan-to-plan diffs (elastic replanning) -------------------------------

# the per-section knobs a replan can flip; ``staging`` lives on the built
# CommSchedule rather than the SyncConfig, so it is diffed separately
_SYNC_KNOBS = ("strategy", "scatter_depth", "chunks", "codec", "mid_codec",
               "pipeline", "path_split")


@dataclass(frozen=True)
class PlanDelta:
    """One knob that changed for one section between two plans."""

    section: str
    knob: str
    before: object
    after: object

    def describe(self) -> str:
        return f"{self.section}: {self.knob} {self.before!r} -> {self.after!r}"


@dataclass(frozen=True)
class PlanDiff:
    """What a replan changed and why.

    ``deltas`` lists every per-section knob flip between sections the two
    plans share (matched by name); ``added``/``removed`` name sections only
    one plan has (shapes appeared/vanished across the replan).  ``reason``
    is the caller's cause — typically the fabric degradation that forced
    the replan.  Totals are the plans' own ``est_total_s`` so the diff
    states the priced cost of the degradation alongside the knob story."""

    reason: str = ""
    deltas: Tuple[PlanDelta, ...] = ()
    added: Tuple[str, ...] = ()
    removed: Tuple[str, ...] = ()
    before_total_s: float = 0.0
    after_total_s: float = 0.0

    @property
    def changed(self) -> bool:
        return bool(self.deltas or self.added or self.removed)

    def describe(self) -> str:
        head = (f"PlanDiff ({self.reason}): " if self.reason
                else "PlanDiff: ")
        head += (f"{len(self.deltas)} knob change(s), "
                 f"est {self.before_total_s * 1e3:.3f} ms -> "
                 f"{self.after_total_s * 1e3:.3f} ms")
        lines = [head]
        lines += [f"  {d.describe()}" for d in self.deltas]
        lines += [f"  + section {n}" for n in self.added]
        lines += [f"  - section {n}" for n in self.removed]
        if not self.changed:
            lines.append("  (no per-section changes — totals repriced only)")
        return "\n".join(lines)


def _section_knobs(section) -> dict:
    knobs = {k: getattr(section.sync, k) for k in _SYNC_KNOBS}
    knobs["staging"] = getattr(section.schedule, "staging", None)
    return knobs


def diff_plans(old, new, reason: str = "") -> PlanDiff:
    """Diff two ``SyncPlan``s (duck-typed: anything with ``.sections``
    carrying ``.name``/``.sync``/``.schedule`` and ``.est_total_s``)
    section-by-section.  ``old`` may be None — every section of ``new``
    then reports as added, which lets callers treat "first plan on a
    degraded fabric" and "replan from a known-good plan" uniformly."""
    new_secs = {s.name: s for s in new.sections}
    old_secs = {} if old is None else {s.name: s for s in old.sections}
    deltas: List[PlanDelta] = []
    for name in sorted(set(old_secs) & set(new_secs)):
        before, after = _section_knobs(old_secs[name]), \
            _section_knobs(new_secs[name])
        for knob in (*_SYNC_KNOBS, "staging"):
            if before[knob] != after[knob]:
                deltas.append(PlanDelta(name, knob, before[knob],
                                        after[knob]))
    return PlanDiff(
        reason=reason,
        deltas=tuple(deltas),
        added=tuple(sorted(set(new_secs) - set(old_secs))),
        removed=tuple(sorted(set(old_secs) - set(new_secs))),
        before_total_s=0.0 if old is None else float(old.est_total_s),
        after_total_s=float(new.est_total_s))
