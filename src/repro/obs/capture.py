"""Capture ``simulate`` calls and export them as trace + drift artifacts.

``capture()`` registers a :func:`repro.sim.fabric_sim.add_observer` hook
for the duration of a ``with`` block and yields the list of
:class:`~repro.sim.fabric_sim.SimObservation` records — one per
``simulate`` call, appended AFTER the result is fully constructed, so
capturing is bitwise non-invasive to the simulation itself.

``export_observation`` turns one observation into the two artifacts the
benchmark harness writes per figure: a Perfetto-loadable
``<name>.trace.json`` (simulated + predicted tracks + pool counters) and
a :class:`~repro.obs.audit.DriftReport` judging every leg against its
contract class.
"""
from __future__ import annotations

import os
from contextlib import contextmanager
from typing import Iterator, List, Tuple

from repro.obs.audit import DriftReport, auto_expectations, compare
from repro.obs.trace import to_chrome_trace, write_chrome_trace
from repro.sim import fabric_sim
from repro.sim.fabric_sim import SimObservation


@contextmanager
def capture() -> Iterator[List[SimObservation]]:
    """Collect every ``simulate`` call made inside the block.

    >>> with capture() as observations:
    ...     simulate(fab, tenants, cost=cm)
    >>> observations[0].result.makespan
    """
    observations: List[SimObservation] = []
    fabric_sim.add_observer(observations.append)
    try:
        yield observations
    finally:
        fabric_sim.remove_observer(observations.append)


def export_observation(obs: SimObservation, out_dir: str,
                       name: str) -> Tuple[str, DriftReport]:
    """Write ``<out_dir>/<name>.trace.json`` for one captured simulate
    call and return ``(trace_path, drift_report)``.  Expectations are
    derived automatically (:func:`~repro.obs.audit.auto_expectations`);
    the predicted tracks render each expectation's lower-bound
    estimate."""
    expectations = auto_expectations(obs)
    estimates = {k: e.lo for k, e in expectations.items()
                 if e.lo is not None}
    trace = to_chrome_trace(obs.result, estimates=estimates,
                            tenants=obs.tenants)
    path = write_chrome_trace(trace, os.path.join(out_dir,
                                                  f"{name}.trace.json"))
    report = compare(obs.result, expectations, tenants=obs.tenants)
    return path, report
