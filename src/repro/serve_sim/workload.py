"""Open-loop serving workloads: who arrives, when, and how big.

The fleet simulator is OPEN-LOOP (the serving-systems sense): sessions
arrive on their own clock — a Poisson process or a recorded trace — and
do NOT slow down when the system backs up, so queueing delay shows up in
the tail instead of silently throttling the offered load (the classic
closed-loop measurement bug).  This module owns that arrival side:

  * :class:`SLOClass` — a named service tier: an arbiter ``priority``
    (mapped onto the NIC/memory pools' weighted max-min machinery) and a
    ``slack`` multiplier turning a session's SOLO price into its
    deadline;
  * :class:`Session` — one inference request: arrival time, prompt and
    output token counts, its SLO class, and a traffic ``kind`` (dense
    all-gather prefill vs MoE all-to-all prefill);
  * :func:`generate_sessions` — the seeded synthetic generator
    (exponential inter-arrivals, lognormal token lengths), reproducible
    bit for bit from ``WorkloadConfig.seed``;
  * :func:`sessions_from_trace` / :func:`load_trace` — replay recorded
    arrivals (JSONL rows) through the same :class:`Session` shape.

Everything here is stdlib-only and fabric-free: turning sessions into
:class:`~repro.sim.fabric_sim.Tenant` programs is ``fleet.py``'s job.
"""
from __future__ import annotations

import json
import random
from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

# the default service tiers: interactive traffic outranks the batch lane
# 4:1 on the arbiters (the weight ratio is the experiment knob, not a
# magic constant) and must finish within 2x its solo price; batch tolerates
# 8x.  Priorities must be > 0 (LaneRequest/MemRequest contract).
DEFAULT_SLO_CLASSES = None  # filled below (dataclass forward ref)


@dataclass(frozen=True)
class SLOClass:
    """One service tier: ``priority`` is the arbiter weight its sessions'
    flows carry (NicPool/MemPool weighted max-min — MUST be > 0), and
    ``slack`` turns a session's solo price into its deadline
    (``deadline = arrival + slack * solo_estimate``)."""

    name: str
    priority: float = 1.0
    slack: float = 4.0

    def __post_init__(self):
        if self.priority <= 0:
            raise ValueError(
                f"SLO class {self.name!r}: priority must be > 0 "
                f"(arbiter weight): {self.priority}")
        if self.slack <= 0:
            raise ValueError(
                f"SLO class {self.name!r}: slack must be > 0: {self.slack}")


DEFAULT_SLO_CLASSES = (
    SLOClass("interactive", priority=4.0, slack=2.0),
    SLOClass("batch", priority=1.0, slack=8.0),
)


@dataclass(frozen=True)
class Session:
    """One inference request as the fleet sees it: ``arrival`` seconds on
    the open-loop clock, ``prompt_tokens`` to prefill, ``output_tokens``
    to decode, its :class:`SLOClass`, and the prefill traffic ``kind``
    (``"dense"`` = all-gather burst, ``"moe"`` = all-to-all dispatch)."""

    uid: int
    arrival: float
    prompt_tokens: int
    output_tokens: int
    slo: SLOClass
    kind: str = "dense"

    def __post_init__(self):
        if self.prompt_tokens < 1 or self.output_tokens < 1:
            raise ValueError(
                f"session {self.uid}: needs >= 1 prompt and output token: "
                f"{self.prompt_tokens} / {self.output_tokens}")
        if self.kind not in ("dense", "moe"):
            raise ValueError(
                f"session {self.uid}: kind must be dense|moe: {self.kind!r}")

    @property
    def name(self) -> str:
        """The tenant-name stem (``s0017`` -> tenants ``s0017p`` /
        ``s0017d``); zero-padded so sorted tenant order is arrival
        order."""
        return f"s{self.uid:04d}"


@dataclass(frozen=True)
class WorkloadConfig:
    """The synthetic generator's knobs.

    ``rate`` is the offered load in sessions/second (Poisson:
    exponential inter-arrivals at mean ``1/rate``); token counts are
    lognormal (the shape every serving trace shows — a body of short
    prompts and a heavy tail) clamped to ``[1, max]``.  ``slo_mix``
    weights the SLO classes by name; ``moe_frac`` of sessions carry MoE
    all-to-all prefill traffic instead of the dense burst.  Everything
    is driven by one ``random.Random(seed)``, so a config is its own
    reproducibility statement."""

    rate: float = 50.0
    sessions: int = 24
    seed: int = 0
    prompt_mean_tokens: float = 512.0
    prompt_sigma: float = 0.6
    prompt_max_tokens: int = 4096
    output_mean_tokens: float = 64.0
    output_sigma: float = 0.5
    output_max_tokens: int = 512
    slo_mix: Tuple[Tuple[str, float], ...] = (("interactive", 0.5),
                                              ("batch", 0.5))
    moe_frac: float = 0.0

    def __post_init__(self):
        if self.rate <= 0 or self.sessions < 1:
            raise ValueError(
                f"need rate > 0 and sessions >= 1: {self.rate}/{self.sessions}")
        if not 0.0 <= self.moe_frac <= 1.0:
            raise ValueError(f"moe_frac must be in [0, 1]: {self.moe_frac}")
        if not self.slo_mix or any(w < 0 for _, w in self.slo_mix) \
                or sum(w for _, w in self.slo_mix) <= 0:
            raise ValueError(f"slo_mix needs positive weights: {self.slo_mix}")


def _lognormal_tokens(rng: random.Random, mean: float, sigma: float,
                      cap: int) -> int:
    """Lognormal token count with the requested ARITHMETIC mean (mu is
    back-solved: E[lognormal] = exp(mu + sigma^2/2)), clamped to
    [1, cap]."""
    import math
    mu = math.log(max(mean, 1.0)) - 0.5 * sigma * sigma
    return max(1, min(cap, int(round(rng.lognormvariate(mu, sigma)))))


def generate_sessions(cfg: WorkloadConfig,
                      classes: Sequence[SLOClass] = DEFAULT_SLO_CLASSES
                      ) -> List[Session]:
    """The seeded open-loop generator: ``cfg.sessions`` sessions with
    exponential inter-arrivals at ``cfg.rate``/s, lognormal token
    counts, SLO classes drawn from ``cfg.slo_mix``, and ``moe_frac`` of
    them carrying MoE prefill.  Same config -> the same session list,
    bit for bit (one ``random.Random(cfg.seed)`` drives every draw in a
    fixed order)."""
    by_name = {c.name: c for c in classes}
    for name, _ in cfg.slo_mix:
        if name not in by_name:
            raise ValueError(
                f"slo_mix names unknown class {name!r}; "
                f"have {sorted(by_name)}")
    rng = random.Random(cfg.seed)
    mix_names = [n for n, _ in cfg.slo_mix]
    mix_wts = [w for _, w in cfg.slo_mix]
    out: List[Session] = []
    t = 0.0
    for uid in range(cfg.sessions):
        t += rng.expovariate(cfg.rate)
        prompt = _lognormal_tokens(rng, cfg.prompt_mean_tokens,
                                   cfg.prompt_sigma, cfg.prompt_max_tokens)
        output = _lognormal_tokens(rng, cfg.output_mean_tokens,
                                   cfg.output_sigma, cfg.output_max_tokens)
        slo = by_name[rng.choices(mix_names, weights=mix_wts, k=1)[0]]
        kind = "moe" if rng.random() < cfg.moe_frac else "dense"
        out.append(Session(uid, t, prompt, output, slo, kind))
    return out


# ---------------------------------------------------------------------------
# Trace-driven arrivals
# ---------------------------------------------------------------------------


def sessions_from_trace(rows: Sequence[Mapping],
                        classes: Sequence[SLOClass] = DEFAULT_SLO_CLASSES
                        ) -> List[Session]:
    """Build sessions from recorded rows (dicts with ``arrival_s``,
    ``prompt_tokens``, ``output_tokens``, optional ``slo`` class name
    and ``kind``) — the trace-driven twin of :func:`generate_sessions`.
    Rows are sorted by arrival; uids are their sorted positions."""
    by_name = {c.name: c for c in classes}
    default = classes[0]
    parsed = sorted(rows, key=lambda r: float(r["arrival_s"]))
    out: List[Session] = []
    for uid, r in enumerate(parsed):
        slo_name = r.get("slo", default.name)
        if slo_name not in by_name:
            raise ValueError(
                f"trace row {uid} names unknown SLO class {slo_name!r}; "
                f"have {sorted(by_name)}")
        out.append(Session(uid, float(r["arrival_s"]),
                           int(r["prompt_tokens"]), int(r["output_tokens"]),
                           by_name[slo_name], str(r.get("kind", "dense"))))
    return out


def load_trace(path: str,
               classes: Sequence[SLOClass] = DEFAULT_SLO_CLASSES
               ) -> List[Session]:
    """Load a JSONL arrival trace (one ``sessions_from_trace`` row per
    line; blank lines and ``#`` comments skipped)."""
    rows: List[dict] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            rows.append(json.loads(line))
    return sessions_from_trace(rows, classes)
