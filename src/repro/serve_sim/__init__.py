"""Fleet-scale serving simulator: open-loop arrivals driven through the
contended pools (see ``workload`` for the arrival side, ``fleet`` for
the session -> Tenant expansion and the fleet scheduler)."""
from repro.serve_sim.fleet import (FleetConfig, FleetResult, SessionMetrics,
                                   SessionPlan, decode_schedule, plan_fleet,
                                   prefill_schedule, simulate_fleet,
                                   solo_estimate_s)
from repro.serve_sim.workload import (DEFAULT_SLO_CLASSES, SLOClass, Session,
                                      WorkloadConfig, generate_sessions,
                                      load_trace, sessions_from_trace)

__all__ = [
    "DEFAULT_SLO_CLASSES", "FleetConfig", "FleetResult", "SLOClass",
    "Session", "SessionMetrics", "SessionPlan", "WorkloadConfig",
    "decode_schedule", "generate_sessions", "load_trace", "plan_fleet",
    "prefill_schedule", "sessions_from_trace", "simulate_fleet",
    "solo_estimate_s",
]
