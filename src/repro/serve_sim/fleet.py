"""Session -> Tenant expansion and the fleet scheduler.

This is where the repo's two halves finally meet: each
:class:`~repro.serve_sim.workload.Session` becomes TWO
:class:`~repro.sim.fabric_sim.Tenant` programs replayed through the
shared pools —

  * ``s0017p`` (prefill): one burst collective over the prompt's sync
    payload — a pipelined all-gather walk (dense) or an all-to-all
    dispatch (MoE) built by the REAL schedule builders, preceded by the
    prompt's compute;
  * ``s0017d`` (decode): ``output_tokens`` rounds of (step compute, one
    small sequential latency-dominated collective).  The decode wire
    payload carries the step's activation sync PLUS the KV-cache append
    bytes, staged ``local`` or ``pool`` per session (the planner prices
    both; a KV working set that outgrows the local budget is forced to
    the pool), and ``kv_read_bw`` lets each step's compute draw KV reads
    from the LOCAL memory channels (the C1 contention surface).

Phases and admission are expressed with ``Tenant.after`` chains, so the
event loop SIMULATES queueing instead of estimating it: a session's
decode runs ``after`` its prefill, and a queued session's prefill runs
``after`` the previous occupant of its batch slot.  The scheduler plans
only slot ASSIGNMENT (greedy earliest-estimated-free, from each
session's solo price); whether the slot is actually free is the
simulator's verdict.

SLO tiers map onto the arbiters: with ``priority_lanes`` each tenant's
flows carry its class's priority through the NicPool/MemPool weighted
max-min (interactive outranks batch); without it every flow weighs 1.0
— the equal-weight baseline ``benchmarks/fig_fleet.py`` compares
against.

The solo contract (the fleet's parity anchor): ONE session on an idle
fabric finishes in exactly ``prefill compute + prefill price +
rounds * (step compute + decode price)`` — :func:`solo_estimate_s`, the
same number ``deadline = slack * solo`` is derived from — because every
phase inherits the sim/cost parity of its schedule.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.cost_model import CostModel, ScheduleEstimate
from repro.core.schedule import (CommSchedule, SyncConfig, build_all_to_all,
                                 build_schedule)
from repro.core.topology import FabricSpec, as_fabric
from repro.core.nicpool import NicPool
from repro.serve_sim.workload import Session
from repro.sim.fabric_sim import (FailureEvent, SimResult, Tenant,
                                  simulate)
from repro.utils.stats import percentile

_ELEM = 4  # float32 wire elements


def _round_up(n: int, k: int) -> int:
    k = max(k, 1)
    return ((max(n, 1) + k - 1) // k) * k


@dataclass(frozen=True)
class FleetConfig:
    """The fleet scheduler's knobs (per-chip bytes, like every payload
    in the cost model).

    ``slots`` is the continuous-batching capacity: at most ``slots``
    sessions hold the engine at once, the rest queue on ``after``
    chains.  ``bytes_per_token`` sizes the prefill sync payload;
    ``decode_sync_bytes`` + ``kv_bytes_per_token`` size each decode
    step's wire leg (activation sync plus the KV append).
    ``kv_local_budget_bytes`` is the per-slot local-DRAM budget: a
    session whose whole KV footprint fits may stage locally (cheaper
    when priced so), one that doesn't is forced to the pool devices.
    ``kv_read_bw`` > 0 makes each decode step's compute draw that much
    bandwidth from the LOCAL channels while it runs (0 = pure-time
    compute).  ``priority_lanes`` maps SLO priorities onto the arbiters;
    False runs the equal-weight baseline.

    ``pool_lanes`` fixes the NIC-pool capacity the fleet contends on;
    ``None`` uses the fabric's own rack pool (``FabricSpec.pool_lanes``).
    This matters: ``simulate``'s default pool SCALES with the tenant
    count (every tenant contributes its lanes — right for the θ-CN rack
    figures, wrong for serving, where the rack's NICs are fixed no
    matter how many sessions arrive).

    ``prefill_path_split`` routes that fraction of every prefill's slow
    sub-flows over the named alternative routes (``SyncConfig
    .path_split`` semantics; the fabric must declare them).  The elastic
    knob for a degraded fleet: after a mid-run lane death shrinks the
    Ethernet pool, replanned schedules shift prefill burst traffic onto
    the surviving routes."""

    slots: int = 8
    bytes_per_token: float = 4096.0
    decode_sync_bytes: float = 16384.0
    kv_bytes_per_token: float = 1024.0
    kv_local_budget_bytes: float = 1e6
    kv_read_bw: float = 0.0
    step_compute_s: float = 20e-6
    prefill_compute_s_per_token: float = 0.25e-6
    chunks: int = 4
    pipeline: bool = True
    priority_lanes: bool = True
    pool_lanes: Optional[float] = None
    prefill_path_split: Optional[Tuple[Tuple[str, float], ...]] = None

    def __post_init__(self):
        if self.slots < 1:
            raise ValueError(f"slots must be >= 1: {self.slots}")
        if self.chunks < 1:
            raise ValueError(f"chunks must be >= 1: {self.chunks}")


@dataclass(frozen=True)
class SessionPlan:
    """One session's compiled plan: its two tenants, their prices, the
    solo estimate the deadline is derived from, and which slot it was
    assigned (``queued_after`` names the slot's previous decode tenant,
    None when the slot was planned free)."""

    session: Session
    prefill: Tenant
    decode: Tenant
    prefill_est: ScheduleEstimate
    decode_est: ScheduleEstimate
    solo_s: float
    deadline_s: float
    slot: int
    queued_after: Optional[str]

    @property
    def staging(self) -> Optional[str]:
        return self.decode.schedule.staging \
            if self.decode.schedule is not None else None


@dataclass(frozen=True)
class SessionMetrics:
    """Per-request serving metrics, all in seconds on the sim clock.
    ``ttft_s`` is first-token time (arrival -> the first decode round's
    last leg); ``tpot_s`` the mean per-output-token time after prefill;
    ``met`` whether the FULL response beat the class deadline."""

    uid: int
    name: str
    slo: str
    kind: str
    arrival: float
    prefill_done: float
    finish: float
    ttft_s: float
    tpot_s: float
    latency_s: float
    deadline_s: float
    met: bool
    output_tokens: int
    staging: Optional[str]


@dataclass(frozen=True)
class FleetResult:
    """A fleet run: the raw :class:`SimResult` plus per-session metrics
    and the aggregate serving numbers the figures plot."""

    sim: SimResult
    plans: Tuple[SessionPlan, ...]
    sessions: Tuple[SessionMetrics, ...]

    @property
    def makespan(self) -> float:
        return self.sim.makespan

    @property
    def goodput_tok_s(self) -> float:
        """Output tokens of DEADLINE-MET sessions per second of
        makespan — the serving goodput the paper's scaling claims are
        about (late tokens don't count)."""
        if self.makespan <= 0:
            return 0.0
        return sum(m.output_tokens for m in self.sessions if m.met) \
            / self.makespan

    @property
    def met_frac(self) -> float:
        return sum(1 for m in self.sessions if m.met) \
            / max(len(self.sessions), 1)

    def of_class(self, slo: str) -> Tuple[SessionMetrics, ...]:
        return tuple(m for m in self.sessions if m.slo == slo)

    def latency_pct(self, q: float, slo: Optional[str] = None) -> float:
        ms = self.of_class(slo) if slo else self.sessions
        return percentile([m.latency_s for m in ms], q)

    def ttft_pct(self, q: float, slo: Optional[str] = None) -> float:
        ms = self.of_class(slo) if slo else self.sessions
        return percentile([m.ttft_s for m in ms], q)

    def describe(self) -> str:
        classes = sorted({m.slo for m in self.sessions})
        lines = [f"FleetResult: {len(self.sessions)} sessions, makespan "
                 f"{self.makespan * 1e3:.2f} ms, goodput "
                 f"{self.goodput_tok_s:.0f} tok/s, "
                 f"met {100 * self.met_frac:.0f}%"]
        for c in classes:
            ms = self.of_class(c)
            lines.append(
                f"  {c}: n={len(ms)} "
                f"ttft p50 {self.ttft_pct(50, c) * 1e3:.2f} ms "
                f"p99 {self.ttft_pct(99, c) * 1e3:.2f} ms | "
                f"latency p50 {self.latency_pct(50, c) * 1e3:.2f} ms "
                f"p99 {self.latency_pct(99, c) * 1e3:.2f} ms | "
                f"met {sum(1 for m in ms if m.met)}/{len(ms)}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Schedule construction (per-session payloads through the real builders)
# ---------------------------------------------------------------------------


def _moe_members(fab: FabricSpec) -> int:
    n = 1
    for t in fab.tiers:
        if t.size > 1:
            n *= t.size
    return n


def prefill_schedule(fab: FabricSpec, s: Session,
                     cfg: FleetConfig) -> CommSchedule:
    """The prompt's burst collective: dense sessions run the pipelined
    hierarchical all-gather walk, MoE sessions the all-to-all dispatch.
    Payloads are rounded up to the builder's divisibility grain so the
    planned chunk count survives (the parity contract needs the
    schedule the estimate priced, not a clamped cousin)."""
    numel = int(math.ceil(s.prompt_tokens * cfg.bytes_per_token / _ELEM))
    if s.kind == "moe":
        n_total = _moe_members(fab)
        row = _round_up(int(math.ceil(numel / n_total)), cfg.chunks)
        sc = SyncConfig(strategy="hier_striped", chunks=cfg.chunks,
                        pipeline=False,
                        path_split=cfg.prefill_path_split)
        return build_all_to_all(fab, sc, (n_total, row))
    sc = SyncConfig(strategy="hier_striped", chunks=cfg.chunks,
                    pipeline=cfg.pipeline,
                    path_split=cfg.prefill_path_split)
    n = _round_up(numel, max(fab.n_fast, 1) * cfg.chunks)
    return build_schedule(fab, sc, (n,))


def decode_schedule(fab: FabricSpec, s: Session, cfg: FleetConfig,
                    cm: CostModel) -> CommSchedule:
    """One decode step's wire leg: activation sync plus the KV append,
    sequential (chunks=1 — at these sizes latency dominates and a
    pipeline would only add per-chunk floors).  KV staging is chosen PER
    SESSION: a KV footprint within the local budget prices ``local`` vs
    ``pool`` and keeps the cheaper (tie -> local, the lower-latency
    placement); one that outgrows the budget is forced to ``pool``."""
    payload = cfg.decode_sync_bytes + cfg.kv_bytes_per_token
    numel = _round_up(int(math.ceil(payload / _ELEM)), max(fab.n_fast, 1))
    sc = SyncConfig(strategy="hier_striped", chunks=1, pipeline=False)
    sched = build_schedule(fab, sc, (numel,))
    if fab.mem is None:
        return sched
    kv_total = (s.prompt_tokens + s.output_tokens) * cfg.kv_bytes_per_token
    if kv_total > cfg.kv_local_budget_bytes:
        return sched.with_staging("pool")
    local = cm.from_schedule(sched.with_staging("local"), mem=True).total_s
    pool = cm.from_schedule(sched.with_staging("pool"), mem=True).total_s
    return sched.with_staging("local" if local <= pool else "pool")


def _step_compute_s(fab: FabricSpec, cfg: FleetConfig) -> float:
    """Effective per-step compute: when the step draws KV reads from the
    local channels, a demand above what they deliver stretches the phase
    (``mem_bytes / deliverable``) — the same floor the sim enforces."""
    if cfg.kv_read_bw <= 0 or fab.mem is None:
        return cfg.step_compute_s
    deliverable = fab.mem.deliverable_bw("local")
    if deliverable <= 0 or cfg.kv_read_bw <= deliverable:
        return cfg.step_compute_s
    return cfg.step_compute_s * cfg.kv_read_bw / deliverable


def solo_estimate_s(s: Session, cfg: FleetConfig, fab: FabricSpec,
                    prefill_est: ScheduleEstimate,
                    decode_est: ScheduleEstimate) -> float:
    """The session's SOLO price — what it costs alone on an idle fabric.
    This is the fleet's parity anchor (the sim must reproduce it for a
    lone session) and the base of the class deadline."""
    prefill = s.prompt_tokens * cfg.prefill_compute_s_per_token \
        + prefill_est.total_s
    decode = s.output_tokens * (_step_compute_s(fab, cfg)
                                + decode_est.total_s)
    return prefill + decode


# ---------------------------------------------------------------------------
# The fleet scheduler
# ---------------------------------------------------------------------------


def plan_fleet(fabric, sessions: Sequence[Session],
               cfg: Optional[FleetConfig] = None,
               cost: Optional[CostModel] = None) -> List[SessionPlan]:
    """Compile sessions into tenant programs and assign batch slots.

    Sessions are taken in arrival order; each goes to the slot with the
    earliest ESTIMATED free time (greedy, from solo prices).  The
    session's prefill always chains ``after`` the slot's previous decode
    tenant — if the estimate was optimistic the simulator still enforces
    the slot capacity, and if it was pessimistic the chain costs nothing
    (the predecessor has already drained).  Deadlines are
    ``arrival + slack * solo`` per the session's SLO class."""
    cfg = cfg or FleetConfig()
    fab = as_fabric(fabric)
    cm = cost or CostModel(fab)
    slot_free = [0.0] * cfg.slots
    slot_tail: List[Optional[str]] = [None] * cfg.slots
    plans: List[SessionPlan] = []
    for s in sorted(sessions, key=lambda x: (x.arrival, x.uid)):
        pre = prefill_schedule(fab, s, cfg)
        dec = decode_schedule(fab, s, cfg, cm)
        mem = fab.mem is not None
        pre_est = cm.from_schedule(pre, mem=True) if mem \
            else cm.from_schedule(pre)
        dec_est = cm.from_schedule(dec, mem=True) if mem \
            else cm.from_schedule(dec)
        solo = solo_estimate_s(s, cfg, fab, pre_est, dec_est)
        pr = s.slo.priority if cfg.priority_lanes else 1.0
        k = min(range(cfg.slots), key=lambda i: (slot_free[i], i))
        queued_after = slot_tail[k]
        prefill = Tenant(
            name=s.name + "p", schedule=pre, start=s.arrival,
            compute_s=s.prompt_tokens * cfg.prefill_compute_s_per_token,
            rounds=1, priority=pr, after=queued_after)
        decode = Tenant(
            name=s.name + "d", schedule=dec, start=s.arrival,
            compute_s=cfg.step_compute_s, rounds=s.output_tokens,
            priority=pr,
            compute_mem_bw=cfg.kv_read_bw if mem else 0.0,
            after=prefill.name)
        plans.append(SessionPlan(
            session=s, prefill=prefill, decode=decode,
            prefill_est=pre_est, decode_est=dec_est, solo_s=solo,
            deadline_s=s.arrival + s.slo.slack * solo, slot=k,
            queued_after=queued_after
            if slot_free[k] > s.arrival + 1e-12 else None))
        slot_free[k] = max(slot_free[k], s.arrival) + solo
        slot_tail[k] = decode.name
    return plans


def _session_metrics(plan: SessionPlan, sim: SimResult) -> SessionMetrics:
    s = plan.session
    prefill_done = sim.finish[plan.prefill.name]
    finish = sim.finish[plan.decode.name]
    round0 = [e.finish for e in sim.tenant_events(plan.decode.name)
              if e.round == 0]
    ttft = (max(round0) if round0 else finish) - s.arrival
    tpot = (finish - prefill_done) / max(s.output_tokens, 1)
    latency = finish - s.arrival
    return SessionMetrics(
        uid=s.uid, name=s.name, slo=s.slo.name, kind=s.kind,
        arrival=s.arrival, prefill_done=prefill_done, finish=finish,
        ttft_s=ttft, tpot_s=tpot, latency_s=latency,
        deadline_s=plan.deadline_s,
        met=finish <= plan.deadline_s + 1e-12,
        output_tokens=s.output_tokens, staging=plan.staging)


def simulate_fleet(fabric, sessions: Sequence[Session],
                   cfg: Optional[FleetConfig] = None,
                   cost: Optional[CostModel] = None,
                   failures: Sequence[FailureEvent] = ()) -> FleetResult:
    """Plan the fleet and replay it through the pools: ONE ``simulate``
    call carries every session's prefill and decode tenant, so
    admission, phase chaining, SLO priorities and KV staging all
    arbitrate against each other — and the run flows through
    ``repro.obs`` (capture/audit/trace) like any other simulate call.
    ``failures`` injects mid-run capacity losses (``lane_down``/
    ``device_down``) into that one call — the schedules are still the
    HEALTHY-fabric plans, so the result shows what the degradation costs
    an un-replanned fleet."""
    cfg = cfg or FleetConfig()
    fab = as_fabric(fabric)
    cm = cost or CostModel(fab)
    plans = plan_fleet(fab, sessions, cfg, cm)
    tenants: List[Tenant] = []
    for p in plans:
        tenants.append(p.prefill)
        tenants.append(p.decode)
    lanes = cfg.pool_lanes if cfg.pool_lanes is not None \
        else (fab.pool_lanes if fab.depth > 1 else 1.0)
    sim = simulate(fab, tenants, pool=NicPool(lanes=lanes), cost=cm,
                   failures=failures)
    metrics = tuple(_session_metrics(p, sim)
                    for p in sorted(plans, key=lambda p: p.session.uid))
    return FleetResult(sim=sim, plans=tuple(plans), sessions=metrics)
