"""Sharded synthetic data pipeline with deterministic resume.

Two sources:
  * ``SyntheticLM`` — zipf-distributed tokens with a planted bigram
    structure (so small models show real loss curves, TinyStories-style),
  * ``UniformLM``   — uniform random tokens (throughput benchmarking).

The pipeline is *step-indexed*: batch(step) is a pure function of
(seed, step), so resuming from a checkpoint at step k reproduces the exact
stream without persisting cursors — the deterministic-resume property the
fault-tolerance tests assert.  Host sharding: each data-parallel host
materializes only its slice (``host_slice``), double-buffered onto device
via :class:`repro.core.staging_utils.StagingBuffers`.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig


@dataclass(frozen=True)
class DataConfig:
    seed: int = 1234
    kind: str = "synthetic"  # synthetic | uniform
    zipf_a: float = 1.2
    bigram_weight: float = 0.7  # structure strength (learnable signal)
    n_bigram_states: int = 64


class TokenPipeline:
    """Deterministic, step-indexed token batches."""

    def __init__(self, arch: ArchConfig, shape: ShapeConfig, cfg: DataConfig,
                 host_index: int = 0, host_count: int = 1):
        assert shape.global_batch % host_count == 0
        self.arch = arch
        self.shape = shape
        self.cfg = cfg
        self.host_index = host_index
        self.host_count = host_count
        self.local_batch = shape.global_batch // host_count
        # planted bigram table (same on all hosts)
        rng = np.random.default_rng(cfg.seed)
        V = arch.vocab
        self._next_tok = rng.integers(0, V, size=(cfg.n_bigram_states,), dtype=np.int64)

    # -- pure function of (seed, step, host) ----------------------------------
    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        V = self.arch.vocab
        B, S = self.local_batch, self.shape.seq_len
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 65_537 + self.host_index)
        if cfg.kind == "uniform":
            toks = rng.integers(0, V, size=(B, S + 1), dtype=np.int64)
        else:
            # zipf base distribution, clipped into vocab
            base = rng.zipf(cfg.zipf_a, size=(B, S + 1)).astype(np.int64)
            toks = np.minimum(base - 1, V - 1)
            # plant bigram structure: with prob bigram_weight the next token
            # is a deterministic function of the previous one
            follow = rng.random((B, S + 1)) < cfg.bigram_weight
            for t in range(1, S + 1):
                nxt = self._next_tok[toks[:, t - 1] % cfg.n_bigram_states]
                toks[:, t] = np.where(follow[:, t], nxt, toks[:, t])
        batch = {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }
        if self.arch.is_encdec:
            batch["frames"] = rng.standard_normal(
                (B, self.arch.encoder.n_frames, self.arch.d_model)).astype(np.float32)
        return batch

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1

    # -- state for checkpointing (trivially small, by design) -----------------
    def state_dict(self, step: int) -> Dict[str, Any]:
        return {"seed": self.cfg.seed, "step": step,
                "host_index": self.host_index, "host_count": self.host_count}

    @staticmethod
    def resume_step(state: Dict[str, Any]) -> int:
        return int(state["step"])
